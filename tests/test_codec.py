"""Uplink wire-format codec laws (core/codec.py).

Property-tested contracts of the sparse + quantized uplink:

  * lossless codecs (sparse, delta) round-trip every registry kind
    bit-exactly — through direct state round-trips, one-shot execute,
    fused sessions (refined divergent fractions, cross-ROI Bernoulli,
    sliding windows), and the 8-device sharded psum path;
  * lossy codecs keep the moments every bound reads exact: quantize
    never touches ``n``/``total``/sketch bins and reconstructs value rows
    within its declared half-step bound; top-k preserves per-stratum
    sketch masses exactly (HT expansion and quantile inversion stay
    sound);
  * byte accounting is hardened: per-window comm is bytes *newly
    shipped* since the previous emit (sliding == tumbling over a span),
    counters are Python ints that stay exact past 2^31 and survive the
    checkpoint round-trip, and a snapshot taken under one codec refuses
    to restore under another.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    PipelineConfig,
    Query,
    StreamSession,
    WindowSpec,
    checkpoint,
    estimators,
    make_table,
    query as aqp,
    windows,
)
from repro.core import codec as wirecodec
from repro.core.estimators import accumulate_column
from repro.data.streams import shenzhen_taxi_stream

KINDS = ("moments", "extrema", "sketch")
LOSSLESS_SPECS = ("sparse", "delta")
ALL_SPECS = ("sparse", "delta", "topk8", "quantize16", "quantize8")

EXACT_FIELDS = ("value", "moe", "ci_low", "ci_high", "relative_error", "n", "population")

PANE = 6_000


@pytest.fixture(scope="module")
def table():
    return make_table(*SHENZHEN_BBOX, precision=5)


@pytest.fixture(scope="module")
def window():
    stream = shenzhen_taxi_stream(num_chunks=1, seed=0)
    return next(windows.count_windows(stream, PANE))


@pytest.fixture(scope="module")
def panes():
    stream = shenzhen_taxi_stream(num_chunks=2, seed=3)
    return list(windows.count_windows(stream, PANE))[:4]


def _rand_stats(rng, s=64, n=3_000, occupied=5, columns=("value", "occupancy")):
    """A sparse registry tree: data concentrated in ``occupied`` strata."""
    stats = {}
    for c in columns:
        strata = rng.choice(s, size=min(occupied, s), replace=False)
        sidx = jnp.asarray(rng.choice(strata, n), jnp.int32)
        vals = jnp.asarray(rng.normal(40, 12, n), jnp.float32)
        mask = jnp.asarray(rng.random(n) < 0.7)
        stats[c] = accumulate_column(KINDS, vals, sidx, mask, s + 1)
    return stats


def _dense_bytes(stats) -> int:
    return sum(int(np.asarray(x).nbytes) for x in jax.tree.leaves(stats))


def _assert_tree_equal(a, b, msg=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=msg)


# -- direct state round-trips --------------------------------------------------


@pytest.mark.parametrize("spec", LOSSLESS_SPECS)
def test_lossless_roundtrip_bit_exact(spec):
    rng = np.random.default_rng(0)
    stats = _rand_stats(rng)
    codec = wirecodec.resolve_codec(spec).for_stream()
    decoded, nbytes = wirecodec.roundtrip(codec, stats)
    _assert_tree_equal(stats, decoded, spec)
    assert 0 < nbytes < _dense_bytes(stats)


@pytest.mark.parametrize("spec", ALL_SPECS)
def test_empty_stats_roundtrip(spec):
    """Zero-occupancy panes cost a few control bytes and decode to the
    identity-filled template bit-exactly (all codecs)."""
    s = 64
    stats = {
        "value": accumulate_column(
            KINDS,
            jnp.zeros((8,), jnp.float32),
            jnp.zeros((8,), jnp.int32),
            jnp.zeros((8,), bool),
            s + 1,
        )
    }
    codec = wirecodec.resolve_codec(spec).for_stream()
    decoded, nbytes = wirecodec.roundtrip(codec, stats)
    _assert_tree_equal(stats, decoded, spec)
    assert nbytes < 128  # preamble + control words only: nothing occupied


@settings(deadline=None, max_examples=10)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    occupied=st.integers(min_value=1, max_value=48),
)
def test_codec_roundtrip_laws_property(seed, occupied):
    """Every codec, arbitrary sparse states: count rows exact, sketch
    masses exact, value rows within the declared bound (exact when
    lossless)."""
    rng = np.random.default_rng(seed)
    stats = _rand_stats(rng, occupied=occupied)
    for spec in ALL_SPECS:
        _check_roundtrip_laws(spec, stats)


def _check_roundtrip_laws(spec, stats):
    codec = wirecodec.resolve_codec(spec).for_stream()
    decoded, nbytes = wirecodec.roundtrip(codec, stats)
    assert nbytes > 0
    lossless = spec in LOSSLESS_SPECS
    for col in stats:
        ms, md = stats[col]["moments"], decoded[col]["moments"]
        # the rows every bound / fpc / HT weight reads are always exact
        np.testing.assert_array_equal(np.asarray(ms.n), np.asarray(md.n))
        np.testing.assert_array_equal(np.asarray(ms.total), np.asarray(md.total))
        bins_s = np.asarray(stats[col]["sketch"].bins)
        bins_d = np.asarray(decoded[col]["sketch"].bins)
        # per-stratum sketch mass is exact under every codec (top-k
        # spreads integer residuals; quantize never touches bins)
        np.testing.assert_array_equal(bins_s.sum(axis=1), bins_d.sum(axis=1))
        if spec.startswith("topk"):
            np.testing.assert_array_equal(
                np.asarray(ms.wsum), np.asarray(md.wsum)
            )
        if lossless:
            _assert_tree_equal(stats[col], decoded[col], f"{spec}:{col}")
        elif spec.startswith("quantize"):
            qmax = {"quantize16": 32764, "quantize8": 124}[spec]
            for name in ("wsum", "m2"):
                a = np.asarray(getattr(ms, name))
                b = np.asarray(getattr(md, name))
                finite = np.isfinite(a)
                amax = float(np.abs(a[finite]).max()) if finite.any() else 0.0
                # declared half-step bound, plus one f32 ulp of the
                # reconstructed value for the final rounding
                bound = 0.5 * (amax / qmax if amax > 0 else 1.0) * (
                    1 + 1e-6
                ) + amax * 2e-7 + 1e-6
                assert np.abs(a - b).max() <= bound, (spec, col, name)
            # mean is recomputed from exact n + reconstructed wsum
            md_mean = np.asarray(md.mean)
            assert np.isfinite(md_mean[np.asarray(ms.n) > 0]).all()


@pytest.mark.parametrize("bits,qmax", ((16, 32764), (8, 124)))
def test_quantize_extrema_sentinels_and_bound(bits, qmax):
    """±inf identity lattice values ride dedicated sentinels (never a
    saturated finite code); finite extrema honor the half-step bound."""
    rng = np.random.default_rng(7)
    stats = _rand_stats(rng, occupied=4)
    codec = wirecodec.QuantizeCodec(bits)
    decoded, _ = wirecodec.roundtrip(codec, stats)
    for col in stats:
        es, ed = stats[col]["extrema"], decoded[col]["extrema"]
        for name in ("min", "max"):
            a = np.asarray(getattr(es, name))
            b = np.asarray(getattr(ed, name))
            np.testing.assert_array_equal(np.isposinf(a), np.isposinf(b))
            np.testing.assert_array_equal(np.isneginf(a), np.isneginf(b))
            finite = np.isfinite(a)
            amax = float(np.abs(a[finite]).max())
            bound = 0.5 * amax / qmax * (1 + 1e-6) + amax * 2e-7 + 1e-6
            assert np.abs(a[finite] - b[finite]).max() <= bound


def test_topk_sketch_totals_and_range():
    """Top-k keeps the k heaviest bins verbatim, confines the residual to
    the occupied [lo, hi] span, and preserves stratum totals exactly."""
    rng = np.random.default_rng(11)
    stats = _rand_stats(rng, occupied=6)
    codec = wirecodec.TopKSketchCodec(4)
    decoded, nb_topk = wirecodec.roundtrip(codec, stats)
    _, nb_sparse = wirecodec.roundtrip(wirecodec.SparseCodec(), stats)
    assert nb_topk < nb_sparse  # the whole point: fewer bins on the wire
    for col in stats:
        a = np.asarray(stats[col]["sketch"].bins)
        b = np.asarray(decoded[col]["sketch"].bins)
        np.testing.assert_array_equal(a.sum(axis=1), b.sum(axis=1))
        for r in range(a.shape[0]):
            nz = np.flatnonzero(a[r])
            if not len(nz):
                np.testing.assert_array_equal(b[r], 0.0)
                continue
            lo, hi = nz[0], nz[-1]
            assert not b[r, :lo].any() and not b[r, hi + 1 :].any(), r
            top = nz[np.argsort(-a[r][nz], kind="stable")][: codec.k]
            np.testing.assert_array_equal(a[r][np.sort(top)], b[r][np.sort(top)])
        # non-sketch rows ride the sparse path bit-exactly
        _assert_tree_equal(stats[col]["moments"], decoded[col]["moments"])
        _assert_tree_equal(stats[col]["extrema"], decoded[col]["extrema"])


def test_delta_stream_frames_and_reference_guard():
    """A delta stream opens with a keyframe, ships cheap XOR frames for
    slowly-changing panes, stays lossless across the sequence, re-keys
    after reset(), and refuses a delta with no reference frame."""
    rng = np.random.default_rng(3)
    base = _rand_stats(rng, occupied=4)
    drift = _rand_stats(np.random.default_rng(4), occupied=4)
    enc = wirecodec.resolve_codec("delta").for_stream()
    frames = []
    for stats in (base, base, drift, base):
        payload = enc.encode(wirecodec.flatten_stats(stats))
        frames.append(payload)
        decoded = wirecodec.unflatten_stats(enc.decode(payload))
        _assert_tree_equal(stats, decoded)
    assert [f.frame for f in frames] == ["key", "delta", "delta", "delta"]
    # an unchanged pane XORs to all-zero rows: near-free on the wire
    assert frames[1].nbytes < frames[0].nbytes
    enc.reset()
    payload = enc.encode(wirecodec.flatten_stats(base))
    assert payload.frame == "key"
    fresh = wirecodec.resolve_codec("delta").for_stream()
    delta_frame = next(f for f in frames if f.frame == "delta")
    with pytest.raises(ValueError, match="keyframe"):
        fresh.decode(delta_frame)


def _row(name, vals, identity=0.0, quantize_ok=False):
    return wirecodec.Row(
        column="c", kind="moments", name=name,
        array=np.asarray(vals, np.float32), quantize_ok=quantize_ok,
        identity=identity,
    )


def _assert_rows_bit_equal(expected, decoded, msg=""):
    for e, d in zip(expected, decoded):
        np.testing.assert_array_equal(
            np.asarray(e.array, np.float32).view(np.uint32),
            np.asarray(d.array, np.float32).view(np.uint32),
            err_msg=f"{msg}:{e.name}",
        )


def test_delta_exact_sign_flip_lossless():
    """Regression: an exact negation (cur == -prev) XORs to the -0.0 bit
    pattern, which a float occupancy test drops as unoccupied — the
    decoder would reconstruct the *old* value and the DPCM stream would
    diverge permanently (5 -> -5 -> 7 decoding as 5 -> 5 -> -7).  Bitwise
    occupancy must ship it.  Covers the extrema variant too: a ``min``
    row's +inf identity flipping to -inf is the same single-bit XOR."""
    codec = wirecodec.DeltaCodec()
    inf = np.inf
    seq = [
        [_row("wsum", [5.0, 0.0, 2.0]), _row("min", [inf, inf], identity=inf)],
        [_row("wsum", [-5.0, 0.0, 2.0]), _row("min", [-inf, inf], identity=inf)],
        [_row("wsum", [7.0, 0.0, 2.0]), _row("min", [-inf, inf], identity=inf)],
    ]
    for i, rows in enumerate(seq):
        payload = codec.encode(rows)
        assert payload.frame == ("key" if i == 0 else "delta")
        _assert_rows_bit_equal(rows, codec.decode(payload), f"frame{i}")


def test_sparse_roundtrip_preserves_sign_of_zero():
    """Regression: a stored -0.0 compares float-equal to the +0.0
    identity; the advertised bit-exact round-trip must keep its sign bit
    (bitwise occupancy), not decode it as +0.0."""
    rows = [_row("wsum", [-0.0, 0.0, 3.0])]
    decoded = wirecodec.SparseCodec().decode(wirecodec.SparseCodec().encode(rows))
    _assert_rows_bit_equal(rows, decoded)
    assert np.signbit(decoded[0].array[0]) and not np.signbit(decoded[0].array[1])


@pytest.mark.parametrize("bits", (16, 8))
def test_quantize_subnormal_amax_scale_floor(bits):
    """Regression: a subnormal amax underflows the f32 scale amax/qmax to
    0 — division by zero, every value clips to qmax and decodes to 0, and
    the declared half-step bound reads 0.  The scale must floor at the
    smallest normal f32 and the declared bound must still hold."""
    amax = float(np.float32(4e-45))  # subnormal; /qmax underflows to 0.0
    rows = [_row("wsum", [amax, 0.0], quantize_ok=True)]
    codec = wirecodec.QuantizeCodec(bits)
    with np.errstate(divide="raise"):
        payload = codec.encode(rows)
    tag, meta, _ = payload.entries[0]
    assert meta[0] == "quant" and meta[2] > 0  # declared bound scale/2 > 0
    decoded = codec.decode(payload)[0].array
    assert np.isfinite(decoded).all()
    assert abs(float(decoded[0]) - amax) <= meta[2]


def test_module_level_restore_reopens_delta_streams(table, panes):
    """Regression: ``checkpoint.restore`` called directly (not through
    ``StreamSession.restore``) must also drop per-stream DPCM state, so
    the first post-restore pane ships a keyframe instead of diffing
    against a reference frame the restored stream never saw."""
    pipe = EdgeCloudPipeline(
        table, PipelineConfig(raw_capacity=PANE, uplink_codec="delta")
    )
    sess = StreamSession(pipe)
    reg = sess.register(Query(aggs=(AggSpec("mean", "value"),)))
    sess.step(jax.random.key(0), panes[0])
    snap = checkpoint.snapshot(sess)
    sess.step(jax.random.key(1), panes[1])  # advances the DPCM reference
    assert any(grp._codec for grp in sess._fusion_groups.values())
    checkpoint.restore(sess, snap)
    assert all(grp._codec == {} for grp in sess._fusion_groups.values())
    # the re-keyed stream still serves lossless estimates
    step = sess.step(jax.random.key(1), panes[1])
    est = step.results[reg.qid].estimates["mean_value"]
    assert np.isfinite(float(est.value))


def test_resolve_codec_specs():
    assert wirecodec.resolve_codec(None) is None
    assert isinstance(wirecodec.resolve_codec("sparse"), wirecodec.SparseCodec)
    assert isinstance(wirecodec.resolve_codec("delta"), wirecodec.DeltaCodec)
    assert isinstance(wirecodec.resolve_codec("delta:sparse"), wirecodec.DeltaCodec)
    assert wirecodec.resolve_codec("topk12").k == 12
    assert wirecodec.resolve_codec("quantize8").bits == 8
    inst = wirecodec.SparseCodec()
    assert wirecodec.resolve_codec(inst) is inst
    for bad in ("gzip", "topk0", "quantize4", 3):
        with pytest.raises(ValueError):
            wirecodec.resolve_codec(bad)
    with pytest.raises(ValueError):
        PipelineConfig(uplink_codec="gzip")  # validated at config time


# -- engine integration: parity with the dense uplink --------------------------


@pytest.mark.parametrize("spec", LOSSLESS_SPECS)
def test_execute_parity_lossless(table, window, spec):
    """One-shot execute under a lossless codec: estimates, bounds, and
    counters bit-identical to the dense uplink; comm_bytes becomes the
    (much smaller) measured encoded size."""
    q = Query(
        aggs=(AggSpec("mean", "value"), AggSpec("var", "value"),
              AggSpec("p50", "value"), AggSpec("max", "value")),
        group_by="neighborhood",
    )
    pipe0 = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=PANE))
    pipe1 = EdgeCloudPipeline(
        table, PipelineConfig(raw_capacity=PANE, uplink_codec=spec)
    )
    r0 = pipe0.execute(q, jax.random.key(3), window, fraction=0.5)
    r1 = pipe1.execute(q, jax.random.key(3), window, fraction=0.5)
    for k in r0.estimates:
        for field in EXACT_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(r0.estimates[k], field)),
                np.asarray(getattr(r1.estimates[k], field)),
                err_msg=f"{spec}:{k}.{field}",
            )
    dense = aqp.preagg_bytes(pipe0.plan(q), table.num_slots)
    assert int(r0.comm_bytes) == dense
    assert 0 < int(r1.comm_bytes) < dense


def test_session_fused_refined_cross_roi_parity(table, panes):
    """The full fused-session surface under a lossless codec — divergent
    fractions (refined per-member passes), cross-ROI Bernoulli fusion, and
    a multi-pane sliding window — emits estimates bit-identical to the
    dense session."""
    roi_south = ((22.45, 22.65), (113.76, 114.64))
    roi_north = ((22.60, 22.86), (113.76, 114.64))
    q_lo = Query(aggs=(AggSpec("mean", "value"), AggSpec("p50", "value")))
    q_hi = Query(aggs=(AggSpec("var", "value"),))
    q_roi = Query(aggs=(AggSpec("mean", "value"),), method="bernoulli", roi=roi_south)
    q_roi2 = Query(aggs=(AggSpec("sum", "occupancy", name="s"),),
                   method="bernoulli", roi=roi_north)

    def drive(cfg):
        pipe = EdgeCloudPipeline(table, cfg)
        sess = StreamSession(pipe)
        regs = [
            sess.register(q_lo, initial_fraction=0.3),
            sess.register(q_hi, initial_fraction=0.8),
            sess.register(q_roi, initial_fraction=0.5),
            sess.register(q_roi2, initial_fraction=0.6),
            sess.register(
                Query(aggs=(AggSpec("mean", "value"),)),
                window=WindowSpec("sliding", size=2),
            ),
        ]
        steps = [
            sess.step(jax.random.fold_in(jax.random.key(9), i), p)
            for i, p in enumerate(panes)
        ]
        return [r.qid for r in regs], steps

    qids0, steps0 = drive(PipelineConfig(raw_capacity=PANE))
    qids1, steps1 = drive(PipelineConfig(raw_capacity=PANE, uplink_codec="sparse"))
    assert qids0 == qids1
    for s0, s1 in zip(steps0, steps1):
        assert set(s0.results) == set(s1.results)
        for qid in s0.results:
            r0, r1 = s0.results[qid], s1.results[qid]
            for k in r0.estimates:
                for field in EXACT_FIELDS:
                    np.testing.assert_array_equal(
                        np.asarray(getattr(r0.estimates[k], field)),
                        np.asarray(getattr(r1.estimates[k], field)),
                        err_msg=f"{qid}:{k}.{field}",
                    )
            assert int(r1.comm_bytes) < int(r0.comm_bytes)


def test_raw_mode_untouched_by_codec(table, window):
    """Raw-mode uplinks ship tuples, not sufficient statistics: a
    configured codec must neither touch their results nor their analytic
    byte accounting."""
    q = Query(aggs=(AggSpec("mean", "value"),), mode="raw")
    pipe0 = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=PANE))
    pipe1 = EdgeCloudPipeline(
        table, PipelineConfig(raw_capacity=PANE, uplink_codec="sparse")
    )
    r0 = pipe0.execute(q, jax.random.key(1), window, fraction=0.5)
    r1 = pipe1.execute(q, jax.random.key(1), window, fraction=0.5)
    assert int(r0.comm_bytes) == int(r1.comm_bytes) == aqp.raw_bytes(
        pipe0.plan(q), PANE
    )
    for field in EXACT_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(r0.estimates["mean_value"], field)),
            np.asarray(getattr(r1.estimates["mean_value"], field)),
        )


# -- hardened byte accounting --------------------------------------------------


def test_sliding_comm_is_newly_shipped_bytes(table, panes):
    """Per-window comm reports bytes *newly shipped* since the previous
    emit — overlapped panes are not re-billed — so sliding and tumbling
    windows account identical totals over the same span."""
    def total_comm(win_spec):
        pipe = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=PANE))
        sess = StreamSession(pipe)
        reg = sess.register(
            Query(aggs=(AggSpec("mean", "value"),)), window=win_spec
        )
        emitted = 0
        for i, p in enumerate(panes):
            step = sess.step(jax.random.fold_in(jax.random.key(2), i), p)
            if reg.qid in step.results:
                emitted += int(step.results[reg.qid].comm_bytes)
        return emitted, sess.total_comm_bytes

    slide, slide_total = total_comm(WindowSpec("sliding", size=2))
    tumble, tumble_total = total_comm(WindowSpec("tumbling", size=1))
    assert slide == tumble == slide_total == tumble_total
    # and the dense model agrees: 4 panes, one fixed-size frame each
    pipe = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=PANE))
    per_pane = aqp.preagg_bytes(
        pipe.plan(Query(aggs=(AggSpec("mean", "value"),))), table.num_slots
    )
    assert tumble == per_pane * len(panes)


def test_comm_counters_exact_past_2p31(table, panes, tmp_path):
    """Cumulative and per-window byte counters are Python ints: forcing a
    near-2^31 carry-in must come out exactly (no int32 wrap, no float
    rounding) and survive the checkpoint round-trip."""
    pipe = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=PANE))
    sess = StreamSession(pipe)
    reg = sess.register(Query(aggs=(AggSpec("mean", "value"),)))
    sess.step(jax.random.key(0), panes[0])
    per_pane = sess.total_comm_bytes
    assert isinstance(per_pane, int) and per_pane > 0
    carry = 2**31 - 8  # an int32 accumulator would wrap on the next pane
    sess.total_comm_bytes += carry
    reg.pending_comm += carry
    step = sess.step(jax.random.key(1), panes[1])
    got = step.results[reg.qid].comm_bytes
    assert int(got) == carry + per_pane > 2**31
    assert sess.total_comm_bytes == carry + 2 * per_pane > 2**31
    # checkpoint round-trip keeps the exact values
    path = tmp_path / "big_comm.npz"
    checkpoint.save(checkpoint.snapshot(sess), path)
    pipe2 = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=PANE))
    sess2 = StreamSession(pipe2)
    reg2 = sess2.register(Query(aggs=(AggSpec("mean", "value"),)))
    checkpoint.restore(sess2, checkpoint.load(path))
    assert sess2.total_comm_bytes == sess.total_comm_bytes
    assert reg2.pending_comm == reg.pending_comm == 0  # reset at the emit


def test_checkpoint_codec_fingerprint_guard(table, panes, tmp_path):
    """A snapshot refuses to restore under a different uplink codec (the
    byte accounting would silently change meaning), while pre-codec
    snapshots — no fingerprint, no pending_comm — still restore."""
    pipe = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=PANE, uplink_codec="sparse"))
    sess = StreamSession(pipe)
    sess.register(Query(aggs=(AggSpec("mean", "value"),)))
    sess.step(jax.random.key(0), panes[0])
    snap = checkpoint.snapshot(sess)
    assert snap["uplink_codec"] == "sparse"

    plain = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=PANE))
    sess_plain = StreamSession(plain)
    sess_plain.register(Query(aggs=(AggSpec("mean", "value"),)))
    with pytest.raises(ValueError, match="uplink codec"):
        checkpoint.restore(sess_plain, snap)

    # forward-compat: an old snapshot without the additive fields restores,
    # reconstructing pending_comm from the ring
    legacy = checkpoint.snapshot(sess_plain)
    del legacy["uplink_codec"]
    for rec in legacy["registrations"]:
        del rec["pending_comm"]
    sess_plain2 = StreamSession(
        EdgeCloudPipeline(table, PipelineConfig(raw_capacity=PANE))
    )
    reg2 = sess_plain2.register(Query(aggs=(AggSpec("mean", "value"),)))
    checkpoint.restore(sess_plain2, legacy)
    assert reg2.pending_comm == 0  # tumbling-1: nothing pending post-emit


# -- empty / all-overflow quantiles through the session ------------------------


def test_empty_and_overflow_quantiles_surface_nan(table):
    """A quantile of an empty histogram is NaN with infinite relative
    error — never a silent 0.  Covers both the fully-empty pane and the
    all-overflow pane (every tuple outside the stratum table, zeroed by
    zero_overflow) through StreamSession, grouped and ungrouped."""
    n = 512
    rng = np.random.default_rng(0)

    def pane(lat, lon):
        return windows.WindowBatch(
            sensor_id=np.zeros(n, np.int32),
            timestamp=np.zeros(n, np.float32),
            lat=np.full(n, lat, np.float32),
            lon=np.full(n, lon, np.float32),
            value=rng.normal(40, 12, n).astype(np.float32),
            valid=np.ones(n, bool),
        )

    empty = windows.WindowBatch(
        sensor_id=np.zeros(n, np.int32),
        timestamp=np.zeros(n, np.float32),
        lat=np.zeros(n, np.float32),
        lon=np.zeros(n, np.float32),
        value=np.zeros(n, np.float32),
        valid=np.zeros(n, bool),
    )
    overflow = pane(lat=0.0, lon=0.0)  # far outside the Shenzhen bbox

    pipe = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=n))
    for win in (empty, overflow):
        sess = StreamSession(pipe)
        r_flat = sess.register(Query(aggs=(AggSpec("p50", "value"),)))
        r_grp = sess.register(
            Query(aggs=(AggSpec("p99", "value"),), group_by="neighborhood")
        )
        step = sess.step(jax.random.key(1), win)
        est = step.results[r_flat.qid].estimates["p50_value"]
        assert np.isnan(float(est.value))
        assert np.isinf(float(est.relative_error))
        grp = step.results[r_grp.qid].estimates["p99_value"]
        assert np.isnan(np.asarray(grp.value)).all()
        assert np.isinf(np.asarray(grp.relative_error)).all()
        # the interval fields themselves never go NaN
        for field in ("moe", "ci_low", "ci_high"):
            assert not np.isnan(np.asarray(getattr(grp, field))).any(), field


# -- multi-device: decode(psum(encode)) on the 8-device mesh -------------------


@pytest.mark.xdist_group("subprocess-heavy")
def test_sharded_psum_codec_parity_8dev():
    """execute_sharded under the sparse codec: the decoded post-psum
    states and every estimate are bit-identical to the dense sharded run
    (the codec sits after the collective, so cross-shard merge order is
    untouched), and the encoded frame is smaller than the dense model."""
    import os
    import subprocess
    import sys

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.core import (
    SHENZHEN_BBOX, AggSpec, EdgeCloudPipeline, PipelineConfig, Query,
    make_table, query as aqp, windows,
)
from repro.data.streams import shenzhen_taxi_stream
from repro.launch.mesh import compat_make_mesh

assert jax.device_count() == 8
mesh = compat_make_mesh((8,), ("data",))
table = make_table(*SHENZHEN_BBOX, precision=5)
window = next(windows.count_windows(shenzhen_taxi_stream(num_chunks=2, seed=0), 32_768))
q = Query(aggs=(AggSpec("mean", "value"), AggSpec("p50", "value"), AggSpec("max", "value")))
pipe0 = EdgeCloudPipeline(table, PipelineConfig(), mesh=mesh)
pipe1 = EdgeCloudPipeline(table, PipelineConfig(uplink_codec="sparse"), mesh=mesh)
r0 = pipe0.execute_sharded(q, jax.random.key(1), window, fraction=0.7)
r1 = pipe1.execute_sharded(q, jax.random.key(1), window, fraction=0.7)
for k in r0.estimates:
    for field in ("value", "moe", "ci_low", "ci_high", "relative_error", "n", "population"):
        np.testing.assert_array_equal(
            np.asarray(getattr(r0.estimates[k], field)),
            np.asarray(getattr(r1.estimates[k], field)), err_msg=f"{k}.{field}")
for la, lb in zip(jax.tree.leaves(r0.stats), jax.tree.leaves(r1.stats)):
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
dense = aqp.preagg_bytes(pipe0.plan(q), table.num_slots)
assert 0 < int(r1.comm_bytes) < dense
print("SHARDED_CODEC_OK", int(r1.comm_bytes), dense)
"""
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=900,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-2000:])
    assert "SHARDED_CODEC_OK" in r.stdout


# -- the AbsSum-style pluggability contract ------------------------------------


def test_plugin_kind_rides_the_codec(table):
    """A registered third-party kind with payload hooks flows through the
    sparse codec untouched — the EDG003-enforced contract in action."""
    from repro.core.estimators import Accumulator, register_accumulator, ACCUMULATORS

    class BitSum(Accumulator):
        kind = "_test_codec_bitsum"

        def accumulate(self, values, stratum_idx, mask, num_slots, counts=None):
            w = jnp.where(mask, jnp.abs(values), 0.0)
            return jax.ops.segment_sum(w, stratum_idx, num_segments=num_slots)

        def merge(self, a, b):
            return a + b

        def merge_panes(self, stacked):
            return stacked.sum(0)

        def psum(self, state, axis_names, shared=None):
            return state

        def zero_overflow(self, state):
            keep = jnp.arange(state.shape[0]) < (state.shape[0] - 1)
            return jnp.where(keep, state, 0.0)

        def payload_vectors(self):
            return 1

        def payload_flatten(self, state):
            return (("s", state, True, 0.0),)

        def payload_unflatten(self, rows):
            return rows["s"]

        def template(self):
            return 0

    register_accumulator(BitSum())
    try:
        rng = np.random.default_rng(5)
        sidx = jnp.asarray(rng.integers(0, 10, 200), jnp.int32)
        vals = jnp.asarray(rng.normal(0, 3, 200), jnp.float32)
        mask = jnp.asarray(rng.random(200) < 0.5)
        stats = {
            "value": accumulate_column(
                ("moments", "_test_codec_bitsum"), vals, sidx, mask, 12
            )
        }
        decoded, nbytes = wirecodec.roundtrip(wirecodec.SparseCodec(), stats)
        _assert_tree_equal(stats, decoded)
        assert nbytes > 0
    finally:
        ACCUMULATORS.pop("_test_codec_bitsum", None)
