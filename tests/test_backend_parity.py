"""Backend parity: the fused multi-column edge-reduce backend ("pallas")
against the per-column segment-ops oracle ("segment") for every registry
accumulator, across modes, grouping, and the legacy shim.

Off-TPU the pallas backend lowers to the fused single-pass stacked segment
reduce (same raw power sums as the MXU kernel); its moments are centered
once cloud-side (``m2 = Σy² − nȳ²``) instead of the segment backend's
two-pass centering, so moment-derived estimates agree to documented fp32
tolerance while count / extrema / sketch states agree exactly."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    PipelineConfig,
    Query,
    make_table,
    windows,
)
from repro.data.streams import shenzhen_taxi_stream

RTOL = 1e-4  # documented fp32 tolerance of the one-pass centering
ATOL = 1e-3

# one aggregate per registry accumulator kind, plus the moment family
PARITY_AGGS = (
    AggSpec("sum", "value"),
    AggSpec("mean", "value"),
    AggSpec("var", "value"),
    AggSpec("count", "value"),
    AggSpec("min", "value"),
    AggSpec("max", "value"),
    AggSpec("p50", "value"),
    AggSpec("p99", "value"),
    AggSpec("mean", "occupancy"),
    AggSpec("max", "occupancy"),
    AggSpec("p50", "occupancy"),
)


@pytest.fixture(scope="module")
def table():
    return make_table(*SHENZHEN_BBOX, precision=5)


@pytest.fixture(scope="module")
def window():
    stream = shenzhen_taxi_stream(num_chunks=2, seed=3)
    return next(windows.count_windows(stream, 25_000))


def _run(table, window, backend, mode="preagg", group_by=None, fraction=0.6):
    cfg = PipelineConfig(backend=backend, raw_capacity=25_000)
    pipe = EdgeCloudPipeline(table, cfg)
    q = Query(aggs=PARITY_AGGS, mode=mode, group_by=group_by)
    return pipe.execute(q, jax.random.key(17), window, fraction=fraction)


@pytest.mark.parametrize("mode", ["preagg", "raw"])
@pytest.mark.parametrize("group_by", [None, "neighborhood"])
def test_backend_parity_all_accumulators(table, window, mode, group_by):
    """Same key, same sampling decisions: every aggregate of every registry
    accumulator agrees across backends within the documented tolerance."""
    seg = _run(table, window, "segment", mode=mode, group_by=group_by)
    pal = _run(table, window, "pallas", mode=mode, group_by=group_by)
    assert int(seg.n_sampled) == int(pal.n_sampled)
    assert int(seg.n_valid) == int(pal.n_valid)
    for spec in PARITY_AGGS:
        for field in ("value", "moe", "n", "population"):
            a = np.asarray(getattr(seg.estimates[spec.key], field))
            b = np.asarray(getattr(pal.estimates[spec.key], field))
            np.testing.assert_allclose(
                a, b, rtol=RTOL, atol=ATOL, err_msg=f"{spec.key}.{field} [{mode}/{group_by}]"
            )
    # non-moment states never pass through the kernel: bit-identical
    for col in ("value", "occupancy"):
        np.testing.assert_array_equal(
            np.asarray(seg.stats[col]["sketch"].bins),
            np.asarray(pal.stats[col]["sketch"].bins),
        )
    np.testing.assert_array_equal(
        np.asarray(seg.stats["value"]["extrema"].min),
        np.asarray(pal.stats["value"]["extrema"].min),
    )


def test_backend_parity_moment_states(table, window):
    """The raw-power-sum adapter reproduces the two-pass moment state: n and
    totals exactly, wsum/m2 within fp32 centering tolerance."""
    seg = _run(table, window, "segment")
    pal = _run(table, window, "pallas")
    a, b = seg.stats["value"]["moments"], pal.stats["value"]["moments"]
    np.testing.assert_array_equal(np.asarray(a.n), np.asarray(b.n))
    np.testing.assert_array_equal(np.asarray(a.total), np.asarray(b.total))
    np.testing.assert_allclose(np.asarray(a.wsum), np.asarray(b.wsum), rtol=1e-6, atol=1e-3)
    np.testing.assert_allclose(np.asarray(a.m2), np.asarray(b.m2), rtol=2e-3, atol=0.5)


def test_backend_legacy_shim_parity(table, window):
    """process_window is backend-agnostic to fp32 tolerance."""
    lat, lon = jnp.asarray(window.lat), jnp.asarray(window.lon)
    val, valid = jnp.asarray(window.value), jnp.asarray(window.valid)
    res = {}
    for backend in ("segment", "pallas"):
        pipe = EdgeCloudPipeline(table, PipelineConfig(backend=backend))
        res[backend] = pipe.process_window(
            jax.random.key(5), lat, lon, val, valid, jnp.float32(0.7)
        )
    for field in ("mean", "sum", "moe"):
        a = float(getattr(res["segment"].estimate, field))
        b = float(getattr(res["pallas"].estimate, field))
        assert b == pytest.approx(a, rel=RTOL, abs=ATOL), field


def test_backend_validation():
    with pytest.raises(ValueError, match="backend"):
        PipelineConfig(backend="cuda")
