"""Backend parity: the fused multi-column edge-reduce backend ("pallas")
and the single-traversal megakernel backend ("fused") against the
per-column segment-ops oracle ("segment") for every registry accumulator,
across modes, grouping, sampling methods, and the legacy shim.

Off-TPU the pallas backend lowers to the fused single-pass stacked segment
reduce (same raw power sums as the MXU kernel); its moments are centered
once cloud-side (``m2 = Σy² − nȳ²``) instead of the segment backend's
two-pass centering, so moment-derived estimates agree to documented fp32
tolerance while count / extrema / sketch states agree exactly.  The fused
backend additionally reproduces the *sampling decisions* in-kernel (the
unified threshold compare); its Bernoulli path runs in latlon mode where
overflow-stratum stat rows deliberately stay zero (counts reconstructed as
residuals, estimation zeroes overflow regardless), so state-level
comparisons for that path go through ``zero_overflow_accs``."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    PipelineConfig,
    Query,
    make_table,
    windows,
)
from repro.data.streams import shenzhen_taxi_stream

RTOL = 1e-4  # documented fp32 tolerance of the one-pass centering
ATOL = 1e-3

# one aggregate per registry accumulator kind, plus the moment family
PARITY_AGGS = (
    AggSpec("sum", "value"),
    AggSpec("mean", "value"),
    AggSpec("var", "value"),
    AggSpec("count", "value"),
    AggSpec("min", "value"),
    AggSpec("max", "value"),
    AggSpec("p50", "value"),
    AggSpec("p99", "value"),
    AggSpec("mean", "occupancy"),
    AggSpec("max", "occupancy"),
    AggSpec("p50", "occupancy"),
)


@pytest.fixture(scope="module")
def table():
    return make_table(*SHENZHEN_BBOX, precision=5)


@pytest.fixture(scope="module")
def window():
    stream = shenzhen_taxi_stream(num_chunks=2, seed=3)
    return next(windows.count_windows(stream, 25_000))


def _run(table, window, backend, mode="preagg", group_by=None, fraction=0.6,
         method="srs", staging_dtype="float32"):
    cfg = PipelineConfig(
        backend=backend, raw_capacity=25_000, staging_dtype=staging_dtype
    )
    pipe = EdgeCloudPipeline(table, cfg)
    q = Query(aggs=PARITY_AGGS, mode=mode, group_by=group_by, method=method)
    return pipe.execute(q, jax.random.key(17), window, fraction=fraction)


def _assert_estimate_parity(seg, other, label, rtol=RTOL, atol=ATOL):
    assert int(seg.n_sampled) == int(other.n_sampled), label
    assert int(seg.n_valid) == int(other.n_valid), label
    assert int(seg.n_overflow) == int(other.n_overflow), label
    for spec in PARITY_AGGS:
        for field in ("value", "moe", "n", "population"):
            a = np.asarray(getattr(seg.estimates[spec.key], field))
            b = np.asarray(getattr(other.estimates[spec.key], field))
            np.testing.assert_allclose(
                a, b, rtol=rtol, atol=atol, err_msg=f"{spec.key}.{field} [{label}]"
            )


@pytest.mark.parametrize("backend", ["pallas", "fused"])
@pytest.mark.parametrize("mode", ["preagg", "raw"])
@pytest.mark.parametrize("group_by", [None, "neighborhood"])
def test_backend_parity_all_accumulators(table, window, backend, mode, group_by):
    """Same key, same sampling decisions: every aggregate of every registry
    accumulator agrees across backends within the documented tolerance."""
    seg = _run(table, window, "segment", mode=mode, group_by=group_by)
    pal = _run(table, window, backend, mode=mode, group_by=group_by)
    _assert_estimate_parity(seg, pal, f"{backend}/{mode}/{group_by}")
    # SRS runs the megakernel in sidx mode (every slot exact) and the
    # pallas backend never routes these kinds through a kernel at all:
    # sketch/extrema states are bit-identical on both backends
    for col in ("value", "occupancy"):
        np.testing.assert_array_equal(
            np.asarray(seg.stats[col]["sketch"].bins),
            np.asarray(pal.stats[col]["sketch"].bins),
        )
    np.testing.assert_array_equal(
        np.asarray(seg.stats["value"]["extrema"].min),
        np.asarray(pal.stats["value"]["extrema"].min),
    )


def test_backend_parity_moment_states(table, window):
    """The raw-power-sum adapter reproduces the two-pass moment state: n and
    totals exactly, wsum/m2 within fp32 centering tolerance."""
    seg = _run(table, window, "segment")
    pal = _run(table, window, "pallas")
    a, b = seg.stats["value"]["moments"], pal.stats["value"]["moments"]
    np.testing.assert_array_equal(np.asarray(a.n), np.asarray(b.n))
    np.testing.assert_array_equal(np.asarray(a.total), np.asarray(b.total))
    np.testing.assert_allclose(np.asarray(a.wsum), np.asarray(b.wsum), rtol=1e-6, atol=1e-3)
    np.testing.assert_allclose(np.asarray(a.m2), np.asarray(b.m2), rtol=2e-3, atol=0.5)


def test_backend_legacy_shim_parity(table, window):
    """process_window is backend-agnostic to fp32 tolerance."""
    lat, lon = jnp.asarray(window.lat), jnp.asarray(window.lon)
    val, valid = jnp.asarray(window.value), jnp.asarray(window.valid)
    res = {}
    for backend in ("segment", "pallas"):
        pipe = EdgeCloudPipeline(table, PipelineConfig(backend=backend))
        res[backend] = pipe.process_window(
            jax.random.key(5), lat, lon, val, valid, jnp.float32(0.7)
        )
    for field in ("mean", "sum", "moe"):
        a = float(getattr(res["segment"].estimate, field))
        b = float(getattr(res["pallas"].estimate, field))
        assert b == pytest.approx(a, rel=RTOL, abs=ATOL), field


def test_backend_validation():
    with pytest.raises(ValueError, match="backend"):
        PipelineConfig(backend="cuda")
    with pytest.raises(ValueError, match="staging_dtype"):
        PipelineConfig(backend="fused", staging_dtype="float16")
    with pytest.raises(ValueError, match="fused"):
        PipelineConfig(backend="segment", staging_dtype="bfloat16")
    # bf16 staging on the fused backend is the supported combination
    PipelineConfig(backend="fused", staging_dtype="bfloat16")


# -- megakernel ("fused") specific paths --------------------------------------


def _zeroed(stats):
    from repro.core import estimators

    return {c: estimators.zero_overflow_accs(kinds) for c, kinds in stats.items()}


def test_fused_bernoulli_latlon_path(table, window):
    """Bernoulli preagg is the full single-traversal path: membership
    resolves in-kernel from lat/lon (no sidx/mask in HBM).  Sampling
    counters are bit-identical; states agree after overflow zeroing (the
    latlon kernel deliberately leaves overflow stat rows zero and the
    pipeline reconstructs overflow *counts* as residuals)."""
    seg = _run(table, window, "segment", method="bernoulli")
    fus = _run(table, window, "fused", method="bernoulli")
    _assert_estimate_parity(seg, fus, "fused/bernoulli")
    za, zb = _zeroed(seg.stats), _zeroed(fus.stats)
    for col in ("value", "occupancy"):
        np.testing.assert_array_equal(
            np.asarray(za[col]["sketch"].bins), np.asarray(zb[col]["sketch"].bins)
        )
        np.testing.assert_allclose(
            np.asarray(za[col]["moments"].total),
            np.asarray(zb[col]["moments"].total),
            rtol=1e-6, atol=1e-3,
        )
    np.testing.assert_array_equal(
        np.asarray(za["value"]["extrema"].min), np.asarray(zb["value"]["extrema"].min)
    )
    np.testing.assert_array_equal(
        np.asarray(za["value"]["extrema"].max), np.asarray(zb["value"]["extrema"].max)
    )


@pytest.mark.parametrize("method", ["srs", "bernoulli"])
def test_fused_nonmultiple_n_and_overflow(table, method):
    """Non-block-multiple N (kernel pads) with a heavy overflow stratum and
    a cross-ROI member mask: fused == segment on every counter/estimate."""
    rng = np.random.default_rng(11)
    n = 777  # not a multiple of any block size
    lat_lo, lat_hi = SHENZHEN_BBOX[0]
    lon_lo, lon_hi = SHENZHEN_BBOX[1]
    win = {
        # ~40% of tuples outside the bbox -> overflow stratum
        "lat": rng.uniform(lat_lo - 0.3, lat_hi + 0.3, n).astype(np.float32),
        "lon": rng.uniform(lon_lo - 0.3, lon_hi + 0.3, n).astype(np.float32),
        "valid": rng.uniform(size=n) < 0.85,
        "value": rng.normal(5.0, 2.0, n).astype(np.float32),
        "occupancy": rng.uniform(0, 4, n).astype(np.float32),
    }
    # an ROI that is a strict sub-box: ok = valid & roi exercises the
    # cross-ROI member masking inside the kernel's ok lane
    roi = ((lat_lo, (lat_lo + lat_hi) / 2), (lon_lo, lon_hi))
    for use_roi in (None, roi):
        q = Query(aggs=PARITY_AGGS, method=method, roi=use_roi)
        outs = {}
        for backend in ("segment", "fused"):
            pipe = EdgeCloudPipeline(table, PipelineConfig(backend=backend))
            outs[backend] = pipe.execute(q, jax.random.key(23), win, fraction=0.5)
        _assert_estimate_parity(
            outs["segment"], outs["fused"], f"{method}/roi={use_roi is not None}"
        )


@pytest.mark.parametrize("method", ["srs", "bernoulli"])
def test_fused_all_masked_pane(table, method):
    """A pane with zero valid tuples: the fused path agrees on the empty
    counters and keeps every stat row at its identity."""
    n = 513
    win = {
        "lat": np.full(n, 22.6, np.float32),
        "lon": np.full(n, 114.0, np.float32),
        "valid": np.zeros(n, bool),
        "value": np.ones(n, np.float32),
        "occupancy": np.ones(n, np.float32),
    }
    q = Query(aggs=PARITY_AGGS, method=method)
    outs = {}
    for backend in ("segment", "fused"):
        pipe = EdgeCloudPipeline(table, PipelineConfig(backend=backend))
        outs[backend] = pipe.execute(q, jax.random.key(3), win, fraction=0.5)
    seg, fus = outs["segment"], outs["fused"]
    assert int(fus.n_sampled) == int(seg.n_sampled) == 0
    assert int(fus.n_valid) == int(seg.n_valid) == 0
    assert int(fus.n_overflow) == int(seg.n_overflow) == 0
    np.testing.assert_array_equal(
        np.asarray(seg.stats["value"]["moments"].n),
        np.asarray(fus.stats["value"]["moments"].n),
    )
    assert float(np.asarray(fus.stats["value"]["moments"].total).sum()) == 0.0


@pytest.mark.parametrize("method", ["srs", "bernoulli"])
def test_fused_refined_member_fractions(table, window, method):
    """The refined fused pass (per-member (M,) fractions from one shared
    draw) through a StreamSession: fused == segment per member, per pane."""
    from repro.core.session import StreamSession

    q1 = Query(aggs=(AggSpec("mean", "value"), AggSpec("min", "value")), method=method)
    q2 = Query(aggs=(AggSpec("sum", "occupancy"), AggSpec("p50", "occupancy")), method=method)
    outs = {}
    for backend in ("segment", "fused"):
        sess = StreamSession(EdgeCloudPipeline(table, PipelineConfig(backend=backend)))
        r1 = sess.register(q1, initial_fraction=0.7)
        r2 = sess.register(q2, initial_fraction=0.3)  # divergent -> refined pass
        step = sess.step(jax.random.key(29), window)
        outs[backend] = (step, r1.qid, r2.qid)
    (s0, qa, qb), (s1, _, _) = outs["segment"], outs["fused"]
    for qid in (qa, qb):
        a, b = s0.results[qid], s1.results[qid]
        assert int(a.n_sampled) == int(b.n_sampled), qid
        for k in a.estimates:
            np.testing.assert_allclose(
                np.asarray(a.estimates[k].value), np.asarray(b.estimates[k].value),
                rtol=RTOL, atol=ATOL, err_msg=f"refined/{method}/{qid}/{k}",
            )


def test_fused_bf16_staging(table, window):
    """bf16 staging only rounds the kernel's value inputs (accumulators
    stay f32): estimates track the f32-staged fused run to bf16 tolerance
    and the sampling decisions are identical (sampling lanes stay f32)."""
    f32 = _run(table, window, "fused", method="bernoulli")
    b16 = _run(table, window, "fused", method="bernoulli", staging_dtype="bfloat16")
    assert int(f32.n_sampled) == int(b16.n_sampled)
    assert int(f32.n_overflow) == int(b16.n_overflow)
    for spec in PARITY_AGGS:
        a = np.asarray(f32.estimates[spec.key].value)
        b = np.asarray(b16.estimates[spec.key].value)
        np.testing.assert_allclose(a, b, rtol=2e-2, atol=0.1, err_msg=spec.key)
