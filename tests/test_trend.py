"""Benchmark trend-history append (benchmarks/trend.py): the gh-pages
series CI builds from each run's BENCH_*.json files."""

import json

import pytest

from benchmarks import trend


def _write(path, data):
    path.write_text(json.dumps(data))
    return str(path)


@pytest.fixture
def measured(tmp_path):
    return [
        _write(tmp_path / "BENCH_query.json", {"fused_speedup_n4": 3.3, "config": {}}),
        _write(tmp_path / "BENCH_kernel.json", {"edge_reduce_fused_speedup_c4": 4.7}),
    ]


def test_append_creates_and_extends_history(tmp_path, measured):
    hist_path = str(tmp_path / "bench-history.json")
    h1 = trend.append(measured, hist_path, sha="aaa", run_id="1", timestamp=1.0)
    assert h1["version"] == trend.HISTORY_VERSION
    assert len(h1["runs"]) == 1
    entry = h1["runs"][0]
    assert entry["sha"] == "aaa"
    assert entry["metrics"]["BENCH_query.json"]["fused_speedup_n4"] == 3.3
    assert entry["metrics"]["BENCH_kernel.json"]["edge_reduce_fused_speedup_c4"] == 4.7
    h2 = trend.append(measured, hist_path, sha="bbb", run_id="2", timestamp=2.0)
    assert [r["sha"] for r in h2["runs"]] == ["aaa", "bbb"]
    # the file on disk round-trips
    assert json.loads(open(hist_path).read())["runs"][1]["sha"] == "bbb"


def test_append_is_idempotent_per_run(tmp_path, measured):
    hist_path = str(tmp_path / "bench-history.json")
    trend.append(measured, hist_path, sha="aaa", run_id="7", timestamp=1.0)
    trend.append(measured, hist_path, sha="aaa", run_id="7", timestamp=2.0)  # CI retry
    h = trend.append(measured, hist_path, sha="bbb", run_id="8", timestamp=3.0)
    assert [r["sha"] for r in h["runs"]] == ["aaa", "bbb"]
    assert h["runs"][0]["timestamp"] == 2.0  # retry replaced its own entry


def test_append_bounds_history_length(tmp_path, measured):
    hist_path = str(tmp_path / "bench-history.json")
    for i in range(5):
        h = trend.append(
            measured, hist_path, sha=f"s{i}", run_id=str(i), timestamp=float(i), max_runs=3
        )
    assert [r["sha"] for r in h["runs"]] == ["s2", "s3", "s4"]  # newest kept


def test_append_rejects_unknown_version(tmp_path, measured):
    hist_path = tmp_path / "bench-history.json"
    _write(hist_path, {"version": 999, "runs": []})
    with pytest.raises(SystemExit, match="version"):
        trend.append(measured, str(hist_path), sha="x", run_id="1")
