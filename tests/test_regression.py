"""Benchmark regression gate (benchmarks/regression.py): tolerance floors,
absolute min/max contract gates, and the static trend page's no-CDN pledge."""

import json
from pathlib import Path

from benchmarks import regression

REPO_ROOT = Path(__file__).resolve().parents[1]


def _gate(tmp_path, measured: dict, gates: dict, tolerance=0.2):
    mpath = tmp_path / "BENCH_x.json"
    mpath.write_text(json.dumps(measured))
    bpath = tmp_path / "baselines.json"
    bpath.write_text(json.dumps({"tolerance": tolerance, "BENCH_x.json": gates}))
    return regression.check([str(mpath)], str(bpath))


def test_tolerance_floor_passes_and_fails(tmp_path):
    ok, _ = _gate(tmp_path, {"speedup": 3.0}, {"speedup": 3.5})  # floor 2.8
    assert ok == []
    bad, _ = _gate(tmp_path, {"speedup": 2.0}, {"speedup": 3.5})
    assert len(bad) == 1 and "regressed" in bad[0]


def test_absolute_min_gate_ignores_tolerance(tmp_path):
    # contract: >= 1.3 exactly, not >= (1 - tol) * 1.3
    bad, report = _gate(tmp_path, {"runtime_speedup": 1.25}, {"runtime_speedup": {"min": 1.3}})
    assert len(bad) == 1 and "absolute floor" in bad[0]
    ok, report = _gate(tmp_path, {"runtime_speedup": 1.31}, {"runtime_speedup": {"min": 1.3}})
    assert ok == []
    assert any("absolute" in line and "OK" in line for line in report)


def test_absolute_max_gate_is_a_ceiling(tmp_path):
    ok, _ = _gate(tmp_path, {"p99_ms": 120.0}, {"p99_ms": {"max": 400}})
    assert ok == []
    bad, _ = _gate(tmp_path, {"p99_ms": 900.0}, {"p99_ms": {"max": 400}})
    assert len(bad) == 1 and "absolute ceiling" in bad[0]


def test_missing_and_malformed_gates_fail_loudly(tmp_path):
    bad, _ = _gate(tmp_path, {"other": 1.0}, {"renamed_metric": {"min": 1.0}})
    assert any("missing" in f for f in bad)
    bad, _ = _gate(tmp_path, {"m": 1.0}, {"m": {"min": 1.0, "max": 2.0}})
    assert any("malformed" in f for f in bad)
    bad, _ = _gate(tmp_path, {"m": 1.0}, {"m": {"target": 1.0}})
    assert any("malformed" in f for f in bad)


def test_committed_baselines_parse_and_gate_shapes_are_valid(tmp_path):
    """Every gate in the committed baselines.json is a number or a
    well-formed {"min"|"max": x} object (a typo'd gate must fail in tests,
    not silently in CI)."""
    baselines = json.loads((REPO_ROOT / "benchmarks" / "baselines.json").read_text())
    sections = {k: v for k, v in baselines.items() if k.startswith("BENCH_")}
    assert "BENCH_ingest.json" in sections
    assert sections["BENCH_ingest.json"]["runtime_speedup"]["min"] >= 1.3
    for name, gates in sections.items():
        # satisfying every gate exactly at its bound must pass
        measured = {}
        for key, g in gates.items():
            if isinstance(g, dict):
                assert set(g) in ({"min"}, {"max"}), f"{name}:{key} malformed {g!r}"
                measured[key] = float(next(iter(g.values())))
            else:
                measured[key] = float(g)
        mpath = tmp_path / name
        mpath.write_text(json.dumps(measured))
        failures, _ = regression.check(
            [str(mpath)], str(REPO_ROOT / "benchmarks" / "baselines.json")
        )
        assert failures == [], failures


def test_trend_page_is_self_contained():
    """benchmarks/trend.html must stay CDN-free (gh-pages renders it with no
    third-party fetches) and read the history file trend.py writes."""
    page = (REPO_ROOT / "benchmarks" / "trend.html").read_text()
    assert "bench-history.json" in page
    assert "<svg" in page or 'createElementNS' in page  # inline SVG rendering
    for marker in ("http://", "https://"):
        for line in page.splitlines():
            if marker in line:
                # the only absolute URL allowed is the SVG namespace constant
                assert "www.w3.org" in line, f"external reference: {line.strip()}"
