"""Geohash encode/decode: reference strings, roundtrip, prefix nesting."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from _hypothesis_fallback import given, settings, st

import jax.numpy as jnp

from repro.core import geohash as G

# Known geohash reference values (from public geohash tools)
KNOWN = [
    (42.605, -5.603, 5, "ezs42"),
    (57.64911, 10.40744, 6, "u4pruy"),
    (39.92324, 116.3906, 6, "wx4g0e"),
    (-25.382708, -49.265506, 6, "6gkzwg"),
]


@pytest.mark.parametrize("lat,lon,p,expected", KNOWN)
def test_known_strings(lat, lon, p, expected):
    got = G.to_strings(np.asarray(G.encode(lat, lon, p)).reshape(1), p)[0]
    assert got == expected


@pytest.mark.parametrize("p", [1, 2, 3, 4, 5, 6])
def test_matches_bisection_reference(rng, p):
    lat = rng.uniform(-85, 85, 200)
    lon = rng.uniform(-175, 175, 200)
    got = G.to_strings(np.asarray(G.encode(jnp.asarray(lat, jnp.float32), jnp.asarray(lon, jnp.float32), p)), p)
    # bisection reference on float32-rounded inputs (same quantization grid)
    ref = [G.encode_host(float(np.float32(a)), float(np.float32(o)), p) for a, o in zip(lat, lon)]
    mismatch = sum(g != r for g, r in zip(got, ref))
    # ulp-boundary cells may differ; must be rare and adjacent
    assert mismatch <= 2


@given(
    lat=st.floats(-89.875, 89.875, allow_nan=False, width=32),
    lon=st.floats(-179.875, 179.875, allow_nan=False, width=32),
    p=st.integers(2, 6),
)
@settings(max_examples=200, deadline=None)
def test_decode_roundtrip_within_cell(lat, lon, p):
    code = G.encode(lat, lon, p)
    dlat, dlon = G.decode(code, p)
    cell_lat, cell_lon = G.cell_size_deg(p)
    assert abs(float(dlat) - lat) <= cell_lat * 0.51
    assert abs(float(dlon) - lon) <= cell_lon * 0.51


@given(
    lat=st.floats(-89.875, 89.875, allow_nan=False, width=32),
    lon=st.floats(-179.875, 179.875, allow_nan=False, width=32),
    p=st.integers(2, 6),
    pp=st.integers(1, 6),
)
@settings(max_examples=200, deadline=None)
def test_prefix_nesting(lat, lon, p, pp):
    """parent(code) equals encoding directly at the coarser precision, and
    string prefixes nest (the geohash hierarchy property)."""
    if pp > p:
        pp, p = p, pp
    code_fine = G.encode(lat, lon, p)
    code_coarse = G.encode(lat, lon, pp)
    assert int(G.parent(code_fine, p, pp)) == int(code_coarse)
    s_fine = G.to_strings(np.asarray(code_fine).reshape(1), p)[0]
    s_coarse = G.to_strings(np.asarray(code_coarse).reshape(1), pp)[0]
    assert s_fine.startswith(s_coarse)


def test_string_roundtrip(rng):
    lat = jnp.asarray(rng.uniform(-85, 85, 50), jnp.float32)
    lon = jnp.asarray(rng.uniform(-175, 175, 50), jnp.float32)
    codes = np.asarray(G.encode(lat, lon, 6))
    strings = G.to_strings(codes, 6)
    back = G.from_strings(strings)
    assert (back == codes.astype(np.uint64)).all()
