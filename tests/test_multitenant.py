"""Multi-tenant serving: incremental fusion planning vs full replanning
(property-tested), batched signature-vmapped finalize parity, the
zero-recompile churn contract, the planner audit trail, and the
``emit_all`` serving read."""

import numpy as np
import pytest

import jax

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    SHENZHEN_BBOX,
    SLO,
    AggSpec,
    EdgeCloudPipeline,
    PipelineConfig,
    Query,
    StreamSession,
    WindowSpec,
    make_table,
    windows,
)
from repro.core.runtime import StreamRuntime
from repro.data.streams import shenzhen_taxi_stream

PANE = 4_000

ROI_SOUTH = ((22.45, 22.66), (113.76, 114.64))
ROI_NORTH = ((22.64, 22.86), (113.76, 114.64))

# tenants spanning several sampling signatures (srs x 2 ROIs, bernoulli,
# raw) while many share *finalize* signatures (same aggs/confidence/columns,
# differing only in ROI/method — exactly what the batched emit exploits)
POOL = (
    Query(aggs=(AggSpec("mean", "value"),), roi=ROI_SOUTH, bootstrap_replicates=0),
    Query(aggs=(AggSpec("mean", "value"),), roi=ROI_NORTH, bootstrap_replicates=0),
    Query(aggs=(AggSpec("mean", "value"),), method="bernoulli", bootstrap_replicates=0),
    Query(aggs=(AggSpec("mean", "occupancy"),), roi=ROI_SOUTH, bootstrap_replicates=0),
    Query(aggs=(AggSpec("sum", "value"), AggSpec("var", "value")), confidence=0.9),
    Query(aggs=(AggSpec("mean", "value"), AggSpec("p50", "value"))),
)


@pytest.fixture(scope="module")
def table():
    return make_table(*SHENZHEN_BBOX, precision=4)


@pytest.fixture(scope="module")
def pipe(table):
    return EdgeCloudPipeline(table, PipelineConfig())


@pytest.fixture(scope="module")
def panes():
    stream = shenzhen_taxi_stream(num_chunks=1, seed=3)
    return list(windows.count_windows(stream, PANE))[:3]


def _partition(sess):
    """fusion_key -> ordered member queries, plus the fused carrier plans."""
    groups = {g.key: [m.query for m in g.members] for g in sess._fusion_groups.values()}
    fused = {g.key: g.fused_plan() for g in sess._fusion_groups.values()}
    return groups, fused


def _estimates_np(res):
    return {
        k: {
            f: np.asarray(getattr(est, f))
            for f in ("value", "moe", "ci_low", "ci_high", "n", "population")
        }
        for k, est in res.estimates.items()
    }


# -- incremental planning == full replanning ---------------------------------


@settings(deadline=None, max_examples=10)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    n_ops=st.integers(min_value=1, max_value=24),
)
def test_incremental_refusion_matches_full_replanning(pipe, panes, seed, n_ops):
    """After ANY register/unregister sequence, the incrementally maintained
    fusion partition equals a fresh session's full replanning over the
    survivors (same groups, same member order, equal fused plans), and
    subsequent stepped estimates are bit-identical."""
    rng = np.random.default_rng(seed)
    inc = StreamSession(pipe, initial_fraction=0.7)
    live = []
    for _ in range(n_ops):
        if live and rng.random() < 0.4:
            inc.unregister(live.pop(int(rng.integers(len(live)))))
        else:
            live.append(inc.register(POOL[int(rng.integers(len(POOL)))]))
    fresh = StreamSession(pipe, initial_fraction=0.7)
    mirror = [fresh.register(reg.query) for reg in inc.registrations]

    inc_groups, inc_fused = _partition(inc)
    fresh_groups, fresh_fused = _partition(fresh)
    assert inc_groups == fresh_groups
    assert inc_fused == fresh_fused
    assert len(inc.plan_log) == n_ops

    if not live:
        return
    key = jax.random.key(seed)
    for pane in panes[:2]:
        key, sub = jax.random.split(key)
        step_inc = inc.step(sub, pane)
        step_fresh = fresh.step(sub, pane)
        for reg, ref in zip(inc.registrations, mirror):
            a = _estimates_np(step_inc.results[reg.qid])
            b = _estimates_np(step_fresh.results[ref.qid])
            assert a.keys() == b.keys()
            for k in a:
                for f in a[k]:
                    np.testing.assert_array_equal(
                        a[k][f], b[k][f], err_msg=f"{k}.{f} seed={seed}"
                    )


# -- batched finalize parity --------------------------------------------------


def test_batched_finalize_matches_per_query_loop(pipe, panes):
    """The signature-vmapped batched emit returns the same estimates,
    fractions, and controller state as the per-query finalize loop — across
    tumbling and sliding windows, grouped/quantile aggs, and QoS."""
    workload = [
        (POOL[0], WindowSpec()),
        (POOL[1], WindowSpec()),
        (POOL[3], WindowSpec()),
        (Query(aggs=(AggSpec("mean", "value"),), roi=ROI_NORTH, bootstrap_replicates=0),
         WindowSpec("sliding", size=2)),
        (Query(aggs=(AggSpec("mean", "occupancy"),), roi=ROI_NORTH, bootstrap_replicates=0),
         WindowSpec("sliding", size=2)),
        (Query(aggs=(AggSpec("mean", "value"), AggSpec("p99", "value"))), WindowSpec()),
        (Query(aggs=(AggSpec("mean", "value"), AggSpec("p99", "value")),
               group_by="neighborhood"), WindowSpec()),
    ]
    sessions = (
        StreamSession(pipe, initial_fraction=0.7, batched_finalize=True),
        StreamSession(pipe, initial_fraction=0.7, batched_finalize=False),
    )
    regs = []
    for sess in sessions:
        regs.append(
            [sess.register(q, window=w, slo=SLO(target_relative_error=0.05))
             for q, w in workload]
        )
    key = jax.random.key(5)
    for pane in panes:
        key, sub = jax.random.split(key)
        steps = [sess.step(sub, pane) for sess in sessions]
        assert set(steps[0].results) == {
            regs[0][i].qid for i, r in enumerate(regs[1]) if regs[1][i].qid in steps[1].results
        }
        for r_a, r_b in zip(regs[0], regs[1]):
            if r_a.qid not in steps[0].results:
                continue
            a = _estimates_np(steps[0].results[r_a.qid])
            b = _estimates_np(steps[1].results[r_b.qid])
            for k in b:
                for f in b[k]:
                    np.testing.assert_allclose(
                        a[k][f], b[k][f], rtol=1e-5, atol=1e-6,
                        err_msg=f"batched vs loop: {k}.{f}",
                    )
    # one vectorized controller update per pane must agree with the
    # singleton-fed update: fractions and EMAs track identically
    for r_a, r_b in zip(regs[0], regs[1]):
        assert np.isclose(r_a.fraction, r_b.fraction, rtol=1e-5)
        assert np.isclose(r_a.re_ema, r_b.re_ema, rtol=1e-5)
        assert r_a.steps == r_b.steps


def test_emit_all_is_batched_and_lazy(pipe, panes):
    """``emit_all`` serves every registration's current window through the
    batched path without advancing panes, and materializes per-tenant
    views only on access."""
    sess = StreamSession(pipe, initial_fraction=0.7)
    regs = [sess.register(POOL[i % 4]) for i in range(8)]
    key = jax.random.key(9)
    step = sess.step(key, panes[0])
    before = sess.pane_index
    out = sess.emit_all(key)
    assert sess.pane_index == before
    assert out._batches, "8 tenants over shared signatures must batch"
    assert set(out) == {r.qid for r in regs}
    # same window, same key -> the serving read reproduces the step's emit
    for reg in regs:
        a = _estimates_np(out[reg.qid])
        b = _estimates_np(step.results[reg.qid])
        for k in a:
            np.testing.assert_allclose(a[k]["value"], b[k]["value"], rtol=1e-6)


# -- compiled-program cache / churn ------------------------------------------


def test_register_churn_performs_zero_recompiles(pipe, panes):
    """A register/unregister storm over structurally-seen queries hits every
    pipeline cache family: compile_count stays flat, hits grow."""
    sess = StreamSession(pipe, initial_fraction=0.7)
    for q in POOL[:4]:
        sess.register(q)
    key = jax.random.key(1)
    sess.step(key, panes[0])  # warm every family for this workload
    sess.emit_all(key)
    before = pipe.cache_snapshot()
    for _ in range(5):
        reg = sess.register(POOL[0])
        sess.unregister(reg)
        sess.register(POOL[2])
        sess.unregister(sess.registrations[-1])
        sess.step(key, panes[0])
        sess.emit_all(key)
    after = pipe.cache_snapshot()
    assert after["compile_count"] == before["compile_count"]
    for family in ("plan", "pass", "finalize"):
        assert after["families"][family]["misses"] == before["families"][family]["misses"]
        assert after["families"][family]["hits"] > before["families"][family]["hits"]


def test_runtime_stats_expose_compile_cache(pipe):
    """RuntimeStats carries the pipeline cache counters (the churn gate's
    observability surface)."""
    sess = StreamSession(pipe, initial_fraction=0.7)
    sess.register(POOL[0])
    stats = StreamRuntime(sess, key=jax.random.key(0)).stats()
    assert stats.compile_cache["compile_count"] == pipe.compile_count
    assert set(stats.compile_cache["families"]) == {
        "plan", "exec", "pass", "refined_pass", "finalize"
    }


# -- planner audit trail ------------------------------------------------------


def test_plan_log_records_admission_decisions(pipe):
    sess = StreamSession(pipe)
    a = sess.register(POOL[0])  # new srs/ROI_SOUTH group
    b = sess.register(POOL[3])  # same sampling signature -> joins
    c = sess.register(POOL[2])  # bernoulli -> new group
    sess.unregister(b)
    sess.unregister(c)
    outcomes = [(d.action, d.outcome, d.group_size) for d in sess.plan_log]
    assert outcomes == [
        ("register", "new-group", 1),
        ("register", "joined", 2),
        ("register", "new-group", 1),
        ("unregister", "left", 1),
        ("unregister", "dissolved", 0),
    ]
    assert [d.seq for d in sess.plan_log] == list(range(5))
    assert sess.plan_log[0].qid == a.qid
    assert sess.plan_log[1].group_key == sess.plan_log[0].group_key
    assert sess.plan_log[2].group_key != sess.plan_log[0].group_key
