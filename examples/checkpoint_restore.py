"""Fault-tolerant sessions: per-query fractions + mid-window restore.

Registers two queries at deliberately divergent fractions — the fused
group refines each member to its *own* fraction via nested HT subsampling
(the 10% query pays ~1/8 the downstream volume of the 80% one) — plus a
differing-ROI Bernoulli pair served by ONE cross-signature pass.  Halfway
through the stream the session is checkpointed and "crashes"; a fresh
session re-registers the same queries, restores the snapshot, and resumes
mid-sliding-window with bit-identical estimates (verified against an
uninterrupted run).

Run:  PYTHONPATH=src python examples/checkpoint_restore.py
"""

import os
import tempfile

import numpy as np

import jax

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    Query,
    StreamSession,
    WindowSpec,
    make_table,
    windows,
)
from repro.data.streams import shenzhen_taxi_stream

PANE = 10_000
N_PANES = 8
CUT = 4

ROI_SOUTH = ((22.45, 22.66), (113.76, 114.64))
ROI_NORTH = ((22.64, 22.86), (113.76, 114.64))


def build_session(pipe):
    sess = StreamSession(pipe)
    regs = {
        "cheap": sess.register(
            Query(aggs=(AggSpec("mean", "value"),)),
            initial_fraction=0.1,
            window=WindowSpec("sliding", size=3),
        ),
        "precise": sess.register(
            Query(aggs=(AggSpec("mean", "value", name="precise_mean"),)),
            initial_fraction=0.8,
            window=WindowSpec("sliding", size=3),
        ),
        "south": sess.register(
            Query(aggs=(AggSpec("mean", "value", name="south"),),
                  method="bernoulli", roi=ROI_SOUTH),
        ),
        "north": sess.register(
            Query(aggs=(AggSpec("mean", "occupancy", name="north"),),
                  method="bernoulli", roi=ROI_NORTH),
        ),
    }
    return sess, regs


def main():
    table = make_table(*SHENZHEN_BBOX, precision=5)
    pipe = EdgeCloudPipeline(table)
    stream = shenzhen_taxi_stream(num_chunks=4, seed=0)
    panes = list(windows.count_windows(stream, PANE))[:N_PANES]
    root = jax.random.key(0)

    sess, regs = build_session(pipe)
    print(f"{len(regs)} queries, {len(sess._groups())} fusion groups "
          "(srs pair refined per-fraction, bernoulli pair fused cross-ROI)\n")

    ckpt_path = os.path.join(tempfile.mkdtemp(), "session.npz")
    for i in range(CUT):
        sess.step(jax.random.fold_in(root, i), panes[i])
        sess.checkpoint(ckpt_path)
    print(f"pane {CUT - 1}: checkpointed to {ckpt_path} "
          f"({os.path.getsize(ckpt_path):,d} B) — simulating a crash\n")
    kept = {n: (r.qid, r.downstream_bytes) for n, r in regs.items()}
    del sess, regs

    sess2, regs2 = build_session(pipe)  # fresh process: re-register, restore
    sess2.restore(ckpt_path)
    for name, (qid, down) in kept.items():
        assert regs2[name].qid == qid and regs2[name].downstream_bytes == down
    print(f"restored at pane_index={sess2.pane_index}; "
          f"downstream so far: cheap {regs2['cheap'].downstream_bytes:,d} B vs "
          f"precise {regs2['precise'].downstream_bytes:,d} B "
          f"({regs2['precise'].downstream_bytes / regs2['cheap'].downstream_bytes:.1f}x)\n")

    # uninterrupted reference for the resumed half
    ref_sess, ref_regs = build_session(pipe)
    for i in range(N_PANES):
        ref_step = ref_sess.step(jax.random.fold_in(root, i), panes[i])
    for i in range(CUT, N_PANES):
        step = sess2.step(jax.random.fold_in(root, i), panes[i])
        cheap = step.results[regs2["cheap"].qid].estimates["mean_value"]
        precise = step.results[regs2["precise"].qid].estimates["precise_mean"]
        print(f"pane {i}: cheap {float(cheap.value):6.3f} ±{float(cheap.moe):.3f} "
              f"(n={int(cheap.n)})   precise {float(precise.value):6.3f} "
              f"±{float(precise.moe):.3f} (n={int(precise.n)})")
    for name in regs2:
        a = ref_step.results[ref_regs[name].qid].estimates
        b = step.results[regs2[name].qid].estimates
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k].value), np.asarray(b[k].value))
            np.testing.assert_array_equal(np.asarray(a[k].moe), np.asarray(b[k].moe))
    print("\nresumed run is bit-identical to the uninterrupted session "
          "(values AND intervals) — windows survive the restart.")


if __name__ == "__main__":
    main()
