"""Async streaming runtime: pipelined ingest, backpressure, drop accounting.

Drives a registered QuerySet with :class:`~repro.core.runtime.StreamRuntime`
instead of a hand-rolled ``session.step`` loop:

  * a producer thread pulls panes from a **bursty** arrival simulator into a
    bounded ingest queue (capacity 4, ``drop-newest`` backpressure);
  * the pane loop double-buffers host→device staging and dispatches without
    ever blocking on the device — pane k+1 stages while pane k reduces;
  * when bursts overrun the queue, shed tuples are *counted, not lost*:
    every drop lands in the accounting chain by cause (``queue_full`` /
    ``shed``) and surfaces in the session totals;
  * load shedding degrades sampling fractions while the queue is saturated
    and restores them when it recovers;
  * one registration is **watched**: its fraction decays while its
    per-stratum means are stable and snaps hot on a change or heartbeat.

Run:  PYTHONPATH=src python examples/streaming_runtime.py
"""

import jax

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    PipelineConfig,
    Query,
    RuntimeConfig,
    StreamRuntime,
    StreamSession,
    WindowSpec,
    feedback,
    make_table,
    windows,
)
from repro.data.sources import BurstySource
from repro.data.streams import shenzhen_taxi_stream

PANE = 8_000
N_PANES = 12


def main():
    table = make_table(*SHENZHEN_BBOX, precision=5)
    pipe = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=PANE))
    sess = StreamSession(pipe, initial_fraction=0.8)

    speed = sess.register(
        Query(aggs=(AggSpec("mean", "value", name="mean_speed"),
                    AggSpec("var", "value", name="var_speed"))),
    )
    occ = sess.register(
        Query(aggs=(AggSpec("mean", "occupancy"),)),
        window=WindowSpec("sliding", size=3),
    )

    stream = shenzhen_taxi_stream(chunk_size=PANE, num_chunks=N_PANES, seed=0)
    panes = list(windows.count_windows(stream, PANE))[:N_PANES]

    # warm the jit caches through a throwaway session sharing the pipe's
    # compiled-pass cache, so the timed run shows steady-state behavior
    # instead of one giant first-pane compile
    warm = StreamSession(pipe, initial_fraction=0.8)
    warm.register(speed.query)
    warm.register(occ.query, window=WindowSpec("sliding", size=3))
    for i in range(3):
        warm.step(jax.random.fold_in(jax.random.key(99), i), panes[0])

    # rush-hour arrivals: bursts of 4 panes back-to-back, short idle gaps —
    # repeated enough to overrun a 4-deep queue and exercise backpressure
    source = BurstySource(panes, burst=4, gap_s=0.005, seed=1, repeat=4)

    rt = StreamRuntime(
        sess,
        key=jax.random.key(0),
        config=RuntimeConfig(
            queue_capacity=4,
            policy="drop-newest",
            load_shedding=True,  # degrade fractions under saturation
        ),
    )
    # event-driven sampling: decay the speed query while the city is quiet,
    # snap hot on a mean shift or every 6th pane as a heartbeat probe
    rt.watch(speed, policy=feedback.EventPolicy(heartbeat_panes=6))

    print(f"offering {len(source.panes)} bursty panes of {PANE} tuples "
          f"through a {rt.queue.capacity}-deep {rt.queue.policy!r} queue")
    history = rt.run(source)

    print(f"\n{'pane':>4} {'mean speed':>10} {'occ (3-pane)':>12} "
          f"{'frac':>5} {'dropped':>8}")
    for step in history[:: max(1, len(history) // 8)]:
        spd = float(step.results[speed.qid].estimates["mean_speed"].value)
        o = step.results.get(occ.qid)
        occ_s = f"{float(o.estimates['mean_occupancy'].value):12.3f}" if o else " " * 12
        print(f"{step.pane_index:>4} {spd:>10.2f} {occ_s} "
              f"{step.fractions[speed.qid]:>5.2f} {step.n_dropped:>8}")

    st = rt.stats()
    print(f"\nprocessed {st.panes_processed}/{len(source.panes)} panes "
          f"({st.tuples_processed} tuples); queue high-water {st.queue_depth_high_water}")
    print(f"dropped by cause: {st.dropped_tuples_by_cause or 'none'} "
          f"({sum(st.dropped_panes_by_cause.values())} whole panes)")
    print(f"shed-mode panes: {st.shed_panes}; session totals "
          f"{sess.total_dropped_by_cause or '{}'}")
    print(f"pane latency p50/p99: {st.pane_latency['p50_ms']:.1f}/"
          f"{st.pane_latency['p99_ms']:.1f} ms; "
          f"overlap efficiency {st.overlap_efficiency:.2f}")


if __name__ == "__main__":
    main()
