"""Declarative AQP queries: one window, many answers.

Shows the query layer end-to-end on the simulated Shenzhen taxi stream:

  * a multi-aggregate query (mean/max speed, mean occupancy, count) with
    95% error bounds from a single 80% stratified sample;
  * the same query grouped by neighborhood (vector answers);
  * a region-of-interest query restricted to a geohash-prefix cell;
  * the preagg vs raw transmission trade-off, per query.

Run:  PYTHONPATH=src python examples/query_api.py
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    PipelineConfig,
    Query,
    geohash,
    make_table,
    windows,
)
from repro.data.streams import shenzhen_taxi_stream


def show(title, result):
    print(f"\n{title}")
    for key, est in sorted(result.estimates.items()):
        v = np.asarray(est.value)
        if v.ndim == 0:
            print(f"  {key:>16} = {float(v):10.3f}  ±{float(est.moe):.4f}")
        else:
            vals = " ".join(f"{x:8.2f}" for x in v)
            print(f"  {key:>16} = [{vals}]")
    print(f"  sampled {int(result.n_sampled):,d}/{int(result.n_valid):,d} tuples; "
          f"edge->cloud payload {int(result.comm_bytes):,d} B")


def main():
    table = make_table(*SHENZHEN_BBOX, precision=5)
    pipe = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=32_000))
    w = next(windows.count_windows(shenzhen_taxi_stream(num_chunks=3, seed=0), 40_000))
    key = jax.random.key(0)

    q = Query(aggs=(
        AggSpec("mean", "value", name="mean_speed"),
        AggSpec("max", "value", name="max_speed"),
        AggSpec("mean", "occupancy"),
        AggSpec("count", "value", name="tuples"),
    ))
    show("city-wide (80% stratified sample, 95% CI)", pipe.execute(q, key, w, fraction=0.8))

    qg = Query(aggs=q.aggs, group_by="neighborhood")
    show(f"grouped by {table.num_neighborhoods} neighborhoods",
         pipe.execute(qg, key, w, fraction=0.8))

    # ROI: the busiest geohash-3 cell of this window
    codes = np.asarray(geohash.encode(jnp.asarray(w.lat), jnp.asarray(w.lon), 3))
    vals, counts = np.unique(codes, return_counts=True)
    prefix = geohash.to_strings(np.asarray([vals[counts.argmax()]], np.uint64), 3)[0]
    qr = Query(aggs=q.aggs, roi=prefix)
    show(f"region of interest: geohash prefix {prefix!r}", pipe.execute(qr, key, w, fraction=0.8))

    # transmission modes: same answers, different uplink bytes
    for mode in ("preagg", "raw"):
        res = pipe.execute(Query(aggs=q.aggs, mode=mode), key, w, fraction=0.8)
        print(f"\nmode={mode:>7}: mean_speed={float(res.estimates['mean_speed'].value):.3f} "
              f"payload={int(res.comm_bytes):,d} B")
    print("\nidentical estimates either way; preagg ships O(strata) bytes, raw "
          "ships the kept sample — pick per query.")


if __name__ == "__main__":
    main()
