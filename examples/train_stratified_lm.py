"""End-to-end driver: train an LM on an EdgeSOS-sampled stream.

Trains a ~100M-parameter qwen1.5-style model (a few hundred steps by
default) where the data plane is the paper's technique: every window of
sequences is stratified-sampled at the QoS fraction, the loss is
Horvitz-Thompson weighted (unbiased for the full stream), and metrics
carry the stratified loss estimate ± margin of error.  Fault tolerance
(checkpoint/restore) and the feedback controller run live.

Default (CPU-sized ~14M model, 200 steps):
  PYTHONPATH=src python examples/train_stratified_lm.py
100M-parameter variant (slower):
  PYTHONPATH=src python examples/train_stratified_lm.py --hundred-m --steps 300
"""

import argparse
import sys

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--hundred-m", action="store_true",
                    help="d_model=512, 12 layers, 32K vocab (~100M params)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    if args.hundred_m:
        # build a ~100M config by overriding the registry entry
        import repro.configs.qwen1_5_0_5b as q

        q.SMOKE = q.CONFIG.replace(
            num_layers=12, d_model=512, num_heads=8, num_kv_heads=8,
            d_ff=1408, vocab_size=32_768, remat="none",
        )
    argv = [
        "--arch", "qwen1.5-0.5b", "--steps", str(args.steps),
        "--batch", "32", "--seq", "256" if args.hundred_m else "128",
        "--fraction", "0.8", "--target-re", "0.05",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50", "--log-every", "10",
    ]
    train_driver.main(argv)


if __name__ == "__main__":
    sys.exit(main())
