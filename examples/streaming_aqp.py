"""Distributed streaming AQP: 8 edge shards, both transmission modes.

Runs the sharded pipeline (shard_map over a data mesh) on the Chicago
air-quality stream: each shard = one edge node sampling independently; the
"cloud" estimate comes from either one psum of per-stratum moments
(pre-agg mode) or an all-gather of compacted raw samples.  Prints the
answers, their agreement, and the upstream byte cost of each mode — the
paper's central bandwidth trade-off, measured.

Run:  PYTHONPATH=src python examples/streaming_aqp.py
(relaunches itself with 8 host devices)
"""

import os
import sys

if os.environ.get("_REPRO_AQP_CHILD") != "1":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["_REPRO_AQP_CHILD"] = "1"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax
import jax.numpy as jnp

from repro.core import CHICAGO_BBOX, make_table, windows
from repro.core.pipeline import EdgeCloudPipeline, PipelineConfig
from repro.data.streams import chicago_aq_stream
from repro.sharding.compat import compat_make_mesh


def main():
    mesh = compat_make_mesh((8,), ("data",))
    table = make_table(*CHICAGO_BBOX, precision=6, neighborhood_precision=4)
    print(f"{len(jax.devices())} edge shards; {table.num_strata} strata")

    stream = chicago_aq_stream(num_chunks=10, seed=1)
    wnds = list(windows.count_windows(stream, window_size=40_000))

    pipes = {
        mode: EdgeCloudPipeline(
            table, PipelineConfig(mode=mode, raw_capacity=6_000), mesh=mesh
        )
        for mode in ("preagg", "raw")
    }
    key = jax.random.key(0)
    print(f"{'win':>3} {'mode':>7} {'mean PM2.5':>10} {'±MoE':>7} {'edge->cloud bytes':>18}")
    for i, w in enumerate(wnds[:4]):
        for mode, pipe in pipes.items():
            res = pipe.process_window_sharded(
                key, jnp.asarray(w.lat, jnp.float32), jnp.asarray(w.lon, jnp.float32),
                jnp.asarray(w.value, jnp.float32), jnp.asarray(w.valid), 0.8,
            )
            e = res.estimate
            print(f"{i:3d} {mode:>7} {float(e.mean):10.3f} {float(e.moe):7.4f} "
                  f"{int(res.comm_bytes):18,d}")
        key, _ = jax.random.split(key)
    print("\nboth modes agree exactly; pre-agg ships O(strata) bytes instead of "
          "O(sample) — the paper's bandwidth claim, quantified.")


if __name__ == "__main__":
    main()
