"""Continuous-query sessions: many registered queries, one sampling pass.

Registers a small workload of concurrent queries over the Shenzhen taxi
stream on one StreamSession:

  * city-wide mean/max speed under a 5% relative-error SLO (tumbling);
  * per-neighborhood occupancy over a sliding 4-pane window;
  * a count+extrema dashboard query on a hopping window (no SLO);

then drives the session pane by pane.  All three share one
stratify+EdgeSOS pass and one collective per pane (they agree on sampling
method/mode/ROI), each query's window is assembled by merging pane
accumulators — raw tuples are touched exactly once — and the vectorized
QoS controller adapts one fraction per query.

Run:  PYTHONPATH=src python examples/continuous_queries.py
"""

import numpy as np

import jax

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    Query,
    SLO,
    StreamSession,
    WindowSpec,
    make_table,
    pane_windows,
)
from repro.data.streams import shenzhen_taxi_stream

PANE = 20_000


def main():
    table = make_table(*SHENZHEN_BBOX, precision=5)
    pipe = EdgeCloudPipeline(table)
    sess = StreamSession(pipe, initial_fraction=0.8)

    speed = sess.register(
        Query(aggs=(AggSpec("mean", "value", name="mean_speed"),
                    AggSpec("max", "value", name="max_speed"))),
        slo=SLO(target_relative_error=0.05),
    )
    occ = sess.register(
        Query(aggs=(AggSpec("mean", "occupancy"),), group_by="neighborhood"),
        slo=SLO(target_relative_error=0.10),
        window=WindowSpec("sliding", size=4),
    )
    dash = sess.register(
        Query(aggs=(AggSpec("count", "value", name="tuples"),
                    AggSpec("min", "value"), AggSpec("max", "occupancy"))),
        window=WindowSpec("hopping", size=4, stride=2),
    )
    names = {speed.qid: "speed", occ.qid: "occupancy", dash.qid: "dashboard"}
    print(f"{len(sess.registrations)} registered queries, "
          f"{len(sess._groups())} fusion group(s)\n")

    panes = pane_windows(shenzhen_taxi_stream(num_chunks=8, seed=0), pane_tuples=PANE)
    for step in sess.run(panes, key=jax.random.key(0)):
        emitted = ", ".join(sorted(names[q] for q in step.results)) or "-"
        fr = " ".join(f"{names[q]}={f:.2f}" for q, f in sorted(step.fractions.items()))
        print(f"pane {step.pane_index}: emitted [{emitted}]  "
              f"uplink {step.comm_bytes:,d} B  fractions: {fr}")
        if speed.qid in step.results:
            est = step.results[speed.qid].estimates["mean_speed"]
            print(f"    mean_speed = {float(est.value):7.3f} ±{float(est.moe):.4f}")
        if occ.qid in step.results:
            v = np.asarray(step.results[occ.qid].estimates["mean_occupancy"].value)
            print(f"    occupancy (sliding 4-pane window, {v.shape[0]} neighborhoods): "
                  f"busiest {np.nanmax(np.where(np.isfinite(v), v, np.nan)):.2f}")
        if dash.qid in step.results:
            res = step.results[dash.qid]
            print(f"    dashboard (hopping): {int(res.estimates['tuples'].value):,d} tuples "
                  f"across last {min(dash.window.size, dash.panes_seen)} panes")

    print(f"\ntotal uplink {sess.total_comm_bytes:,d} B for the whole QuerySet — "
          "one sampling pass per pane serves every registered query.")


if __name__ == "__main__":
    main()
