"""Quickstart: the paper's full pipeline in ~60 lines.

Simulates a Shenzhen-like taxi stream, runs EdgeSOS stratified sampling +
the stratified estimators with rigorous error bounds (paper eqs 4-10), and
lets the QoS feedback loop adapt the sampling fraction to a relative-error
SLO — the end-to-end EdgeApproxGeo workflow (Algorithm 2) on one host.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.core import AggSpec, Query, SHENZHEN_BBOX, SLO, make_table, windows
from repro.core.pipeline import EdgeCloudPipeline, PipelineConfig
from repro.data.streams import shenzhen_taxi_stream


def main():
    # 1. spatial model: Geohash-6 strata over the city, Geohash-4 neighborhoods
    table = make_table(*SHENZHEN_BBOX, precision=6, neighborhood_precision=4)
    print(f"stratum table: {table.num_strata} geohash-6 cells, "
          f"{table.num_neighborhoods} neighborhoods")

    # 2. the pipeline (pre-aggregated transmission mode, 95% CIs)
    pipe = EdgeCloudPipeline(table, PipelineConfig(method="srs", mode="preagg"))

    # 3. continuous query with an SLO: keep relative error under 0.5%
    slo = SLO(target_relative_error=0.005, min_fraction=0.05)

    # 4. tumbling count-windows over the simulated stream (paper's ~20K knee)
    stream = shenzhen_taxi_stream(num_chunks=12, seed=0)
    wnds = windows.count_windows(stream, window_size=20_000)

    history, ctrl = pipe.run_stream(wnds, slo=slo, initial_fraction=0.8,
                                    key=jax.random.key(0))
    print(f"{'win':>3} {'mean speed':>10} {'±MoE':>7} {'RE%':>6} {'frac':>5} {'kept':>6}")
    for i, (res, frac) in enumerate(history):
        e = res.estimate
        print(f"{i:3d} {float(e.mean):10.2f} {float(e.moe):7.3f} "
              f"{100*float(e.relative_error):6.3f} {frac:5.2f} {int(res.n_sampled):6d}")
    print(f"\nfinal sampling fraction chosen by the QoS loop: {float(ctrl.fraction):.2f}")
    print("(answers are reported as mean ± MoE at 95% confidence — paper eq 9)")

    # 5. beyond the single estimate: declarative multi-aggregate queries
    # (see examples/query_api.py for group-by, ROI, and transmission modes)
    w = next(windows.count_windows(shenzhen_taxi_stream(num_chunks=2, seed=1), 20_000))
    q = Query(aggs=(AggSpec("mean", "value"), AggSpec("max", "value"),
                    AggSpec("mean", "occupancy")))
    res = pipe.execute(q, jax.random.key(1), w, fraction=float(ctrl.fraction))
    print("\none window, one sample, three answers:")
    for k, e in sorted(res.estimates.items()):
        print(f"  {k:>16} = {float(e.value):8.3f} ±{float(e.moe):.4f}")


if __name__ == "__main__":
    main()
