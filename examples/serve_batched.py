"""Batched serving demo: continuous batching over prefill/decode.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

from repro.launch import serve


def main():
    serve.main([
        "--arch", "qwen1.5-0.5b", "--requests", "8", "--batch", "4",
        "--prompt-len", "32", "--max-new", "16",
    ])


if __name__ == "__main__":
    main()
